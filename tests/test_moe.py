"""MoE dispatch implementations must agree with each other (same routing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map
from repro.config import MoEConfig
from repro.models import moe as M


def _setup(rng, e=8, k=2, d=16, f=24, shared=0, mlp="swiglu"):
    mcfg = MoEConfig(num_experts=e, top_k=k, expert_d_ff=f,
                     num_shared_experts=shared, shared_d_ff=f if shared else 0,
                     capacity_factor=4.0)   # high cf: no drops -> exact equality
    p = M.init_moe(jax.random.PRNGKey(0), d, mcfg, mlp, jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 6, d)), jnp.float32)
    return mcfg, p, x


@pytest.mark.parametrize("mlp", ["swiglu", "gelu_mlp"])
@pytest.mark.parametrize("shared", [0, 2])
def test_dense_vs_sorted(rng, mlp, shared):
    mcfg, p, x = _setup(rng, shared=shared, mlp=mlp)
    y_dense, _ = M.moe_dense(p, mcfg, x)
    y_sorted, aux = M.moe_sorted(p, mcfg, x.reshape(-1, x.shape[-1]))
    assert float(aux["dropped_frac"]) == 0.0
    np.testing.assert_allclose(
        np.asarray(y_dense).reshape(-1, x.shape[-1]),
        np.asarray(y_sorted), atol=1e-4,
    )


def test_sorted_vs_gathered(rng):
    mcfg, p, x = _setup(rng)
    x2d = x.reshape(-1, x.shape[-1])
    y_sorted, _ = M.moe_sorted(p, mcfg, x2d)
    y_gathered, miss, _ = M.moe_gathered(p, mcfg, x2d)
    assert not bool(miss.any())
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_gathered),
                               atol=1e-4)


def test_epsum_single_axis_matches_sorted(rng):
    """epsum under a size-1 model axis == sorted (the collective degenerates)."""
    mcfg, p, x = _setup(rng)
    x2d = x.reshape(-1, x.shape[-1])
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P

    def fn(p_, x_):
        return M.moe_epsum_local(p_, mcfg, x_, ep_axis="model", ep_size=1)

    f = shard_map(
        fn, mesh=mesh,
        in_specs=({"router": P(None, None),
                   "experts": {kk: P("model", None, None) for kk in p["experts"]}},
                  P("data", None)),
        out_specs=(P("data", None), P()),
        check_vma=False,
    )
    y_ep, _ = jax.jit(f)(p, x2d)
    y_sorted, _ = M.moe_sorted(p, mcfg, x2d)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_sorted), atol=1e-4)


def test_slot_lut_miss_drops_expert(rng):
    """Residency path: a missing expert contributes nothing; resident experts
    keep their exact contribution."""
    mcfg, p, x = _setup(rng, e=4, k=2)
    x2d = x.reshape(-1, x.shape[-1])
    logits = M.router_logits(p, x2d)
    ids, weights, _ = M.topk_route(logits, mcfg)
    # slots hold experts 0 and 1 only; 2,3 miss
    num_slots = 2
    slot_buffer = {
        n: jnp.concatenate([p["experts"][n][:2],
                            jnp.zeros_like(p["experts"][n][:1])])
        for n in p["experts"]
    }
    lut = jnp.asarray([0, 1, num_slots, num_slots], jnp.int32)
    y, miss = M.moe_apply_routed(p, x2d, ids, weights,
                                 slot_buffer=slot_buffer, lut=lut)
    assert bool(miss.any()) == bool((np.asarray(ids) >= 2).any())
    # reconstruct: full path minus missed contributions
    y_full, _ = M.moe_apply_routed(p, x2d, ids, weights)
    w_missed = np.asarray(weights) * np.asarray(miss)
    # recompute missed expert contributions with numpy
    from repro.core.engine import _np_ffn

    hw = {n: np.asarray(p["experts"][n]) for n in p["experts"]}
    corr = np.zeros_like(np.asarray(y))
    for t, j in zip(*np.nonzero(np.asarray(miss))):
        corr[t] += w_missed[t, j] * _np_ffn(hw, int(np.asarray(ids)[t, j]),
                                            np.asarray(x2d)[t])
    np.testing.assert_allclose(np.asarray(y) + corr, np.asarray(y_full),
                               atol=2e-3)


def test_capacity_drops_counted(rng):
    mcfg, p, x = _setup(rng)
    mcfg_tight = MoEConfig(num_experts=8, top_k=2, expert_d_ff=24,
                           capacity_factor=0.25)
    _, aux = M.moe_sorted(p, mcfg_tight, x.reshape(-1, x.shape[-1]))
    assert float(aux["dropped_frac"]) > 0.0


def test_aux_losses_finite(rng):
    mcfg, p, x = _setup(rng)
    _, aux = M.moe_dense(p, mcfg, x)
    assert np.isfinite(float(aux["load_balance"]))
    assert np.isfinite(float(aux["router_z"]))
    assert float(aux["load_balance"]) >= 1.0 - 1e-6   # >= 1 by Cauchy-Schwarz
