"""Data pipeline determinism + sharding-rule coverage over every arch."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.config import ShardingConfig, get_config
from repro.configs import ALL_ARCHS
from repro.configs.shapes import SHAPES
from repro.data import ShardedLoader, SyntheticSpec, batch_at_step
from repro.distributed import sharding as shr
from repro.models import init_params
from repro.training import init_train_state

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 1000), st.integers(0, 4))
def test_batch_deterministic(step, seed):
    spec = SyntheticSpec(vocab_size=512, seq_len=32, global_batch=2, seed=seed)
    t1, l1 = batch_at_step(spec, step)
    t2, l2 = batch_at_step(spec, step)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)
    assert (l1[:, :-1] == t1[:, 1:]).all()
    assert (l1[:, -1] == -1).all()


def test_topic_stream_recurs():
    """Topic cycling: the same topic's token distribution recurs with the
    cycle period (the workload driving cyclical residency return)."""
    spec = SyntheticSpec(vocab_size=4096, seq_len=64, global_batch=1,
                         kind="topic", num_topics=4, topic_len=64)
    chunks = [batch_at_step(spec, s)[0] for s in range(8)]
    sets = [set(c.reshape(-1).tolist()) for c in chunks]
    # step s and s+4 share a topic -> high overlap; s and s+1 differ
    same = len(sets[0] & sets[4]) / max(len(sets[0] | sets[4]), 1)
    diff = len(sets[0] & sets[1]) / max(len(sets[0] | sets[1]), 1)
    assert same > diff


def test_loader_resumes_at_step():
    spec = SyntheticSpec(vocab_size=128, seq_len=16, global_batch=2)
    l1 = ShardedLoader(spec, start_step=5)
    step, t, _ = next(l1)
    l1.close()
    assert step == 5
    np.testing.assert_array_equal(np.asarray(t), batch_at_step(spec, 5)[0])


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_cover_all_leaves(arch):
    """Every parameter leaf of every arch gets a rank-compatible spec —
    the dry-run depends on this never raising."""
    cfg = get_config(arch)
    sh = ShardingConfig()
    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0))
    )
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    for path, leaf in flat:
        for fsdp in (False, True):
            spec = shr.param_spec(path, leaf, cfg, sh, fsdp=fsdp)
            assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)


@pytest.mark.parametrize("arch", ["dbrx-132b", "recurrentgemma-2b"])
def test_state_specs_cover_decode_state(arch):
    from repro.models import transformer as tfm

    cfg = get_config(arch)
    sh = ShardingConfig()
    state_shape = jax.eval_shape(lambda: tfm.zero_state(cfg, 8, 1024))
    flat = jax.tree_util.tree_flatten_with_path(state_shape)[0]
    for path, leaf in flat:
        spec = shr.state_spec(path, leaf, cfg, sh, SHAPES["decode_32k"])
        assert len(spec) <= len(leaf.shape)


def test_opt_specs_shard_moments():
    cfg = get_config("starcoder2-3b")
    sh = ShardingConfig()
    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    state_shape = jax.eval_shape(lambda p: init_train_state(cfg, p, sh), params_shape)
    shr.set_dp_size_hint(16)
    flat = jax.tree_util.tree_flatten_with_path(state_shape["opt"]["m"])[0]
    sharded = 0
    for path, leaf in flat:
        spec = shr.opt_spec(("m",) + tuple(path), leaf, cfg, sh)
        if any(s is not None for s in spec):
            sharded += 1
    assert sharded > 0          # ZeRO-1 actually shards something
