"""Shared fixtures. Smoke tests see ONE cpu device (the 512-device flag is set
only inside repro.launch.dryrun, never globally)."""
import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.configs import reduce_for_smoke
from repro.models import init_params

jax.config.update("jax_platform_name", "cpu")

_PARAMS_CACHE = {}


def params_for(arch: str):
    """Session-cached reduced params (init is the slow part on 1 core)."""
    if arch not in _PARAMS_CACHE:
        cfg = reduce_for_smoke(get_config(arch))
        _PARAMS_CACHE[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _PARAMS_CACHE[arch]


@pytest.fixture
def rng():
    return np.random.default_rng(0)
