"""Trainer: optimizer math, microbatch equivalence, loss goes down, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import params_for
from repro.compat import shard_map
from repro.config import RunConfig
from repro.data import SyntheticSpec, batch_at_step
from repro.models.transformer import Runtime
from repro.training import init_train_state, make_train_step
from repro.training.optimizer import adamw_init, adamw_update, global_norm, lr_at


def test_lr_schedule():
    run = RunConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(run, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(run, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr_at(run, jnp.int32(100))) < 2e-4      # cosine floor 10%
    assert float(lr_at(run, jnp.int32(50))) < 1e-3


def test_adamw_step_moves_params():
    run = RunConfig(learning_rate=1e-2, warmup_steps=0)
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    grads = {"w": jnp.ones((4, 4))}
    new_p, new_opt, m = adamw_update(params, grads, opt, run)
    assert float(new_opt["step"]) == 1
    assert not np.allclose(np.asarray(new_p["w"]), 1.0)
    assert float(m["grad_norm"]) == pytest.approx(4.0)


def test_grad_clip_applied():
    run = RunConfig(learning_rate=1e-2, grad_clip=0.1, warmup_steps=0,
                    weight_decay=0.0)
    params = {"w": jnp.zeros((2,))}
    opt = adamw_init(params)
    big = {"w": jnp.full((2,), 100.0)}
    small = {"w": jnp.full((2,), 100.0) * 0.1 / global_norm(big)}
    p1, o1, _ = adamw_update(params, big, opt, run)
    p2, o2, _ = adamw_update(params, small, adamw_init(params), run)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), atol=1e-6)


def test_microbatch_equivalence(rng):
    """num_micro=1 and num_micro=2 produce (nearly) the same updated params."""
    cfg, params = params_for("starcoder2-3b")
    rt = Runtime()
    run = RunConfig(learning_rate=1e-3, warmup_steps=0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    s1 = init_train_state(cfg, params)
    s2 = init_train_state(cfg, params)
    f1 = jax.jit(make_train_step(cfg, rt, run, num_micro=1))
    f2 = jax.jit(make_train_step(cfg, rt, run, num_micro=2))
    s1, m1 = f1(s1, tokens, tokens)
    s2, m2 = f2(s2, tokens, tokens)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "xlstm-350m"])
def test_loss_decreases(arch):
    cfg, params = params_for(arch)
    rt = Runtime()
    run = RunConfig(learning_rate=3e-3, warmup_steps=1)
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=24, global_batch=4,
                         kind="topic", num_topics=2, topic_len=8)
    state = init_train_state(cfg, params)
    step_fn = jax.jit(make_train_step(cfg, rt, run))
    losses = []
    for i in range(5):
        t, l = batch_at_step(spec, i)
        state, m = step_fn(state, jnp.asarray(t), jnp.asarray(l))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_int8_ef_compression_unbiased():
    """Quantize + error feedback: averaged over steps, the compressed gradient
    converges to the true gradient (EF eats the bias)."""
    from repro.training.compression import compressed_psum_pod, ef_init

    g_true = {"w": jnp.asarray(np.linspace(-1, 1, 64).reshape(8, 8), jnp.float32)}
    ef = jax.tree.map(lambda x: x[None].astype(jnp.bfloat16),
                      jax.tree.map(jnp.zeros_like, g_true))
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P

    def step(ef):
        f = shard_map(
            lambda e: compressed_psum_pod(g_true, e, axis="pod", pod_count=1),
            mesh=mesh, in_specs=(P(),), out_specs=(P(), P()), check_vma=False,
        )
        return f(ef)

    acc = jnp.zeros((8, 8))
    n = 20
    for _ in range(n):
        out, ef = step(ef)
        acc = acc + out["w"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g_true["w"]),
                               atol=5e-3)
