"""Hypothesis property tests for the paged KV pool: under arbitrary
interleavings of reserve/ensure/release (random request joins and leaves),
no page is ever leaked, double-allocated, or handed out twice; reservations
are a hard ceiling; and attention through an arbitrary page permutation is
bitwise identical to the contiguous cache (the paging exactness contract,
over drawn shapes rather than the tier-1 suite's fixed ones)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.serving.kv_pool import KVPagePool, PagePoolError

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@given(
    num_pages=st.integers(2, 24),
    page_size=st.integers(1, 8),
    row_pages=st.integers(1, 8),
    ops=st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 10_000)), max_size=80
    ),
)
def test_pool_never_leaks_or_double_frees(num_pages, page_size, row_pages, ops):
    """Model-checked churn: a shadow model tracks every uid's reservation and
    allocation; after every op the pool's own ``check()`` invariants hold,
    the free/in-use counts sum to the pool, and release hands back exactly
    what was allocated."""
    row_pages = min(row_pages, num_pages)
    pool = KVPagePool(num_pages, page_size, row_pages)
    reserved = {}   # uid -> pages reserved
    allocated = {}  # uid -> pages physically held
    uid = 0
    for op, arg in ops:
        if op == 0:  # join
            need = 1 + arg % row_pages
            ok = pool.reserve(uid, need)
            # reservable capacity is the pool minus every live reservation
            # (allocated or not) — physical occupancy doesn't matter
            assert ok == (need <= pool.num_pages - sum(reserved.values()))
            if ok:
                reserved[uid] = need
                allocated[uid] = 0
            uid += 1
        elif op == 1 and reserved:  # grow
            u = sorted(reserved)[arg % len(reserved)]
            tokens = 1 + arg % (reserved[u] * page_size)
            want = pool.pages_for(tokens)
            if want > reserved[u]:
                with pytest.raises(PagePoolError):
                    pool.ensure(u, tokens)
            else:
                pool.ensure(u, tokens)
                allocated[u] = max(allocated[u], want)
        elif op == 2 and reserved:  # leave
            u = sorted(reserved)[arg % len(reserved)]
            freed = pool.release(u)
            assert freed == allocated.pop(u)
            del reserved[u]
        pool.check()
        assert pool.pages_in_use == sum(allocated.values())
        assert pool.pages_in_use + pool.pages_free == pool.num_pages
        assert pool.pages_reservable == pool.num_pages - sum(reserved.values())
    for u in sorted(reserved):
        pool.release(u)
    pool.check()
    assert pool.pages_free == pool.num_pages and pool.pages_in_use == 0


@given(
    num_pages=st.integers(1, 16),
    page_size=st.integers(1, 8),
    tokens=st.integers(0, 200),
)
def test_pages_for_is_ceil_clamped_to_row(num_pages, page_size, tokens):
    pool = KVPagePool(num_pages, page_size, min(4, num_pages))
    want = pool.pages_for(tokens)
    assert 0 <= want <= pool.row_pages
    if tokens <= pool.row_pages * page_size:
        assert want == -(-tokens // page_size)
    else:
        assert want == pool.row_pages  # ring cache: cap at one row's worth


@given(
    b=st.integers(1, 3),
    n_pp=st.integers(1, 4),
    ps=st.sampled_from([1, 2, 4]),
    extra=st.integers(0, 3),
    seed=st.integers(0, 2**16),
)
def test_paged_attention_bitwise_property(b, n_pp, ps, extra, seed):
    """For any batch size, page geometry, page permutation, and ragged
    lengths, attention over the paged planes equals the contiguous cache
    bit-for-bit — unreferenced pages hold large garbage, so any stray read
    would show up immediately."""
    import jax
    import jax.numpy as jnp

    from repro.config.base import AttentionConfig
    from repro.models import attention as attn

    rng = np.random.default_rng(seed)
    acfg = AttentionConfig(num_heads=2, num_kv_heads=1, head_dim=4)
    d_model = 8
    p = attn.init_attention(jax.random.PRNGKey(0), d_model, acfg, jnp.float32)
    cap = n_pp * ps
    P = 1 + b * n_pp + extra  # scratch + tables + unreferenced spares
    cl = rng.integers(0, 3 * cap, b).astype(np.int32)  # wrapped ring lengths
    x = rng.standard_normal((b, 1, d_model)).astype(np.float32)
    ck = rng.standard_normal((b, cap, 1, 4)).astype(np.float32)
    cv = rng.standard_normal((b, cap, 1, 4)).astype(np.float32)
    y_ref, _ = attn.attention_decode(
        p, acfg, jnp.asarray(x), {"k": jnp.asarray(ck), "v": jnp.asarray(cv)},
        jnp.asarray(cl),
    )
    perm = rng.permutation(np.arange(1, P))[: b * n_pp].reshape(b, n_pp)
    perm = perm.astype(np.int32)
    pk = rng.standard_normal((P, ps, 1, 4)).astype(np.float32) * 1e3
    pv = rng.standard_normal((P, ps, 1, 4)).astype(np.float32) * 1e3
    for i in range(b):
        for j in range(n_pp):
            pk[perm[i, j]] = ck[i, j * ps:(j + 1) * ps]
            pv[perm[i, j]] = cv[i, j * ps:(j + 1) * ps]
    y_pg, _ = attn.attention_decode(
        p, acfg, jnp.asarray(x), {"k": jnp.asarray(pk), "v": jnp.asarray(pv)},
        jnp.asarray(cl), page_table=jnp.asarray(perm),
    )
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pg))
