# One reproducible invocation per CI concern (documented in ROADMAP.md).
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: dev-deps tier1 ci bench bench-decode

dev-deps:          ## install test-only deps (hypothesis property coverage)
	$(PYTHON) -m pip install -r requirements-dev.txt

tier1:             ## the ROADMAP tier-1 gate (skips hypothesis modules if absent)
	$(PYTHON) -m pytest -x -q

ci: dev-deps tier1 ## "green" in one command: dev deps + full tier-1 run

bench:             ## all paper-table / kernel / hot-path benchmarks (emits BENCH_decode.json)
	$(PYTHON) -m benchmarks.run

bench-decode:      ## only the decode hot-path micro-benchmark (quick perf iteration)
	$(PYTHON) -m benchmarks.decode_hot_path
