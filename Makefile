# One reproducible invocation per CI concern (documented in ROADMAP.md).
PYTHON ?= python
SHELL := /bin/bash
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: dev-deps tier1 ci bench bench-decode

dev-deps:          ## install test-only deps (hypothesis property coverage)
	$(PYTHON) -m pip install -r requirements-dev.txt

tier1:             ## the ROADMAP tier-1 gate (skips hypothesis modules if absent);
                   ## prints the pass-count delta vs the CHANGES.md tail
	@set -o pipefail; $(PYTHON) -m pytest -x -q 2>&1 | tee .tier1.log; st=$$?; \
	$(PYTHON) tools/tier1_delta.py .tier1.log CHANGES.md; exit $$st

ci: dev-deps tier1 ## "green" in one command: dev deps + full tier-1 run

bench:             ## all paper-table / kernel / hot-path benchmarks (emits BENCH_decode.json)
	$(PYTHON) -m benchmarks.run

bench-decode:      ## decode hot-path micro-benchmark incl. the speculative
                   ## spec[K] row family (appends spec rows to BENCH_decode.json)
	$(PYTHON) -m benchmarks.decode_hot_path --spec-k 2,4,8
