# One reproducible invocation per CI concern (documented in ROADMAP.md).
PYTHON ?= python
SHELL := /bin/bash
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: dev-deps tier1 ci bench bench-decode smoke-int4 smoke-prefill smoke-serve-cb smoke-prefetch smoke-trace smoke-sample

dev-deps:          ## install test-only deps (hypothesis property coverage)
	$(PYTHON) -m pip install -r requirements-dev.txt

tier1:             ## the ROADMAP tier-1 gate (skips hypothesis modules if absent);
                   ## prints the pass-count delta vs the CHANGES.md tail
	@set -o pipefail; $(PYTHON) -m pytest -x -q 2>&1 | tee .tier1.log; st=$$?; \
	$(PYTHON) tools/tier1_delta.py .tier1.log CHANGES.md; exit $$st

smoke-int4:        ## fast packed-path smoke: rotary decode + spec windows on
                   ## grouped-int4 slots (reduced config, a few tokens)
	$(PYTHON) -m repro.launch.serve --arch qwen2-moe-a2.7b --engine rotary \
	  --residency rotary --quantization int4 --batch 2 --requests 2 \
	  --prompt-len 8 --max-new 4 --spec-k 2 --cache-len 64

smoke-prefill:     ## long-prompt chunked-prefill smoke: rotary serve ingesting
                   ## the prompt at one compiled launch per power-of-two chunk
	$(PYTHON) -m repro.launch.serve --arch qwen2-moe-a2.7b --engine rotary \
	  --residency rotary --batch 2 --requests 2 --prompt-len 96 --max-new 4 \
	  --prefill-chunk 32 --cache-len 128

smoke-serve-cb:    ## continuous-batching serve smoke: seeded Poisson arrivals
                   ## joining/leaving live windows over the paged KV pool,
                   ## rotary residency + speculative windows on the CB path
	$(PYTHON) -m repro.launch.serve --arch qwen2-moe-a2.7b --engine batch \
	  --residency rotary --spec-cap 4 --arrival-rate 40 --requests 6 \
	  --batch-slots 4 --prompt-len 10 --max-new 6 --cache-len 64 \
	  --kv-page-size 8

smoke-prefetch:    ## asynchronous-prefetch smoke: slot-starved rotary serve
                   ## with double-buffered shadow generations + speculative
                   ## windows (uploads hide under window compute, misses
                   ## re-launch the compiled step)
	$(PYTHON) -m repro.launch.serve --arch qwen2-moe-a2.7b --engine rotary \
	  --residency rotary --slots 6 --prefetch --batch 2 --requests 2 \
	  --prompt-len 8 --max-new 6 --spec-k 2 --cache-len 64

smoke-trace:       ## observability smoke: traced rotary+prefetch serve writes
                   ## a Perfetto trace, the contract auditor replays it, and
                   ## the CB engine's Prometheus exposition is scraped once
	$(PYTHON) -m repro.launch.serve --arch qwen2-moe-a2.7b --engine rotary \
	  --residency rotary --slots 6 --prefetch --batch 2 --requests 2 \
	  --prompt-len 8 --max-new 6 --spec-k 2 --cache-len 64 \
	  --trace-out .smoke_trace.json
	$(PYTHON) -m repro.obs .smoke_trace.json
	$(PYTHON) tools/trace_view.py .smoke_trace.json --top 10
	$(PYTHON) -m repro.launch.serve --arch qwen2-moe-a2.7b --engine batch \
	  --residency rotary --spec-cap 2 --requests 3 --batch-slots 2 \
	  --prompt-len 8 --max-new 4 --cache-len 64 --kv-page-size 8 \
	  --trace-out .smoke_trace_cb.json --metrics-port 9109
	$(PYTHON) -m repro.obs .smoke_trace_cb.json

smoke-sample:      ## sampled-serving smoke: temperature-0.8 rotary serve with
                   ## spec windows on int4 slots, run TWICE with the same
                   ## seeds — asserts the accept-rate telemetry is on record
                   ## and the seeded token streams reproduce bitwise
	$(PYTHON) -m repro.launch.serve --arch qwen2-moe-a2.7b --engine rotary \
	  --residency rotary --quantization int4 --batch 2 --requests 2 \
	  --prompt-len 8 --max-new 6 --spec-k 4 --cache-len 64 \
	  --temperature 0.8 --top-k 20 --top-p 0.95 --sample-seed 7 \
	  | tee .smoke_sample_a.log
	$(PYTHON) -m repro.launch.serve --arch qwen2-moe-a2.7b --engine rotary \
	  --residency rotary --quantization int4 --batch 2 --requests 2 \
	  --prompt-len 8 --max-new 6 --spec-k 4 --cache-len 64 \
	  --temperature 0.8 --top-k 20 --top-p 0.95 --sample-seed 7 \
	  > .smoke_sample_b.log
	grep -q "accept_rate" .smoke_sample_a.log
	grep -q "spec_windows" .smoke_sample_a.log
	grep "^req " .smoke_sample_a.log > .smoke_sample_a.req
	grep "^req " .smoke_sample_b.log > .smoke_sample_b.req
	cmp .smoke_sample_a.req .smoke_sample_b.req

ci: dev-deps tier1 smoke-int4 smoke-prefill smoke-serve-cb smoke-prefetch smoke-trace smoke-sample ## "green" in one command: dev deps + tier-1 + int4, prefill, CB-serve, prefetch, trace & sampled smokes

bench:             ## all paper-table / kernel / hot-path benchmarks (emits BENCH_decode.json)
	$(PYTHON) -m benchmarks.run

bench-decode:      ## decode hot-path micro-benchmark incl. the speculative
                   ## spec[K] and quantized @int8/@int4 row families
	$(PYTHON) -m benchmarks.decode_hot_path --spec-k 2,4,8 --quantization int8,int4
